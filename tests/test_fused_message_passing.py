"""Fused message-passing megakernels (DESIGN.md §3): forward + gradient
equivalence vs the unfused reference on packed synthetic batches, a
hypothesis sweep over ragged bond/angle distributions, rotation
equivariance of the fused force readout, and the packed-GatedMLP
checkpoint migration.  All run on CPU via REPRO_KERNELS_INTERPRET=1."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.batching import BatchCapacities, batch_crystals
from repro.core.chgnet import CHGNetConfig, chgnet_apply, chgnet_init
from repro.core.interaction import (
    gated_mlp_init,
    gated_mlp_legacy_template,
    pack_gated_mlp_params,
)
from repro.core.losses import LossWeights, chgnet_loss
from repro.core.neighbors import Crystal, build_graph
from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# op level: kernel vs oracle on raw sorted layouts
# ---------------------------------------------------------------------------

def _sorted_edges(rng, num_edges, num_segments, n_real):
    ids = np.sort(rng.integers(0, num_segments, n_real)).astype(np.int32)
    seg = np.zeros(num_edges, np.int32)
    seg[:n_real] = ids
    offs = np.searchsorted(ids, np.arange(num_segments + 1)).astype(np.int32)
    return jnp.asarray(seg), jnp.asarray(offs)


def _atom_op_inputs(rng, a, e_rows, d, n_real):
    seg, offs = _sorted_edges(rng, e_rows, a, n_real)
    nbr = jnp.asarray(rng.integers(0, a, e_rows).astype(np.int32))
    f = lambda *s: jnp.asarray(rng.normal(0, 1, s), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.1, (3 * d, 2 * d)), jnp.float32)
    mlp = (w, f(2 * d), jnp.asarray(rng.uniform(.5, 1.5, (2 * d,)),
                                    jnp.float32), f(2 * d))
    return (f(a, d), f(e_rows, d), f(e_rows, d)) + mlp + (seg, nbr, offs)


@pytest.mark.parametrize("a,e_rows,d,n_real", [
    (16, 200, 32, 180),   # padded tail
    (9, 64, 64, 64),      # no padding, unaligned rows
    (8, 32, 16, 0),       # all edges padded
])
def test_fused_atom_conv_matches_oracle(a, e_rows, d, n_real):
    rng = np.random.default_rng(a + n_real)
    args = _atom_op_inputs(rng, a, e_rows, d, n_real)
    out = ops.fused_atom_conv(*args)
    want = ref.fused_atom_conv_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_fused_atom_conv_gradients_match_oracle():
    rng = np.random.default_rng(7)
    v, e, e_a, w, b, lns, lnb, seg, nbr, offs = _atom_op_inputs(
        rng, 12, 128, 32, 100)
    # fixed cotangent: compares the VJPs themselves, not forward rounding
    # amplified through a nonlinear loss (model-level tests cover that)
    cot = jnp.asarray(rng.normal(0, 1, (12, 32)), jnp.float32)

    def loss(fn, vv, ee, ww):
        out = fn(vv, ee, e_a, ww, b, lns, lnb, seg, nbr, offs)
        return jnp.vdot(out, cot)

    g_f = jax.grad(lambda *p: loss(ops.fused_atom_conv, *p),
                   argnums=(0, 1, 2))(v, e, w)
    g_r = jax.grad(lambda *p: loss(ref.fused_atom_conv_ref, *p),
                   argnums=(0, 1, 2))(v, e, w)
    for got, want in zip(g_f, g_r):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def _bond_op_inputs(rng, a, b_rows, e_rows, d, n_real):
    seg, offs = _sorted_edges(rng, e_rows, b_rows, n_real)
    ik = jnp.asarray(rng.integers(0, b_rows, e_rows).astype(np.int32))
    ctr = jnp.asarray(rng.integers(0, a, e_rows).astype(np.int32))
    f = lambda *s: jnp.asarray(rng.normal(0, 1, s), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.1, (4 * d, 2 * d)), jnp.float32)
    mlp = (w, f(2 * d), jnp.asarray(rng.uniform(.5, 1.5, (2 * d,)),
                                    jnp.float32), f(2 * d))
    return (f(a, d), f(b_rows, d), f(e_rows, d), f(b_rows, d)) + mlp + \
        (seg, ik, ctr, offs)


@pytest.mark.parametrize("a,b_rows,e_rows,d,n_real", [
    (10, 48, 300, 32, 260),
    (6, 17, 40, 16, 40),
    (5, 12, 24, 8, 0),
])
def test_fused_bond_conv_matches_oracle(a, b_rows, e_rows, d, n_real):
    rng = np.random.default_rng(b_rows + n_real)
    args = _bond_op_inputs(rng, a, b_rows, e_rows, d, n_real)
    out = ops.fused_bond_conv(*args)
    want = ref.fused_bond_conv_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_fused_bond_conv_gradients_match_oracle():
    rng = np.random.default_rng(3)
    v, e, a, e_b, w, b, lns, lnb, seg, ik, ctr, offs = _bond_op_inputs(
        rng, 8, 32, 96, 16, 80)
    cot = jnp.asarray(rng.normal(0, 1, (32, 16)), jnp.float32)

    def loss(fn, ee, eb, ww):
        out = fn(v, ee, a, eb, ww, b, lns, lnb, seg, ik, ctr, offs)
        return jnp.vdot(out, cot)

    g_f = jax.grad(lambda *p: loss(ops.fused_bond_conv, *p),
                   argnums=(0, 1, 2))(e, e_b, w)
    g_r = jax.grad(lambda *p: loss(ref.fused_bond_conv_ref, *p),
                   argnums=(0, 1, 2))(e, e_b, w)
    for got, want in zip(g_f, g_r):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_fused_force_readout_matches_oracle_incl_grad():
    rng = np.random.default_rng(11)
    a, e_rows, d, n_real = 14, 180, 32, 150
    seg, offs = _sorted_edges(rng, e_rows, a, n_real)
    e = jnp.asarray(rng.normal(0, 1, (e_rows, d)), jnp.float32)
    xh = rng.normal(0, 1, (e_rows, 3)).astype(np.float32)
    xh /= np.linalg.norm(xh, axis=1, keepdims=True)
    xh = jnp.asarray(xh)
    w1 = jnp.asarray(rng.normal(0, .1, (d, d)), jnp.float32)
    b1 = jnp.asarray(rng.normal(0, .1, (d,)), jnp.float32)
    w2 = jnp.asarray(rng.normal(0, .1, (d, 1)), jnp.float32)
    b2 = jnp.asarray(rng.normal(0, .1, (1,)), jnp.float32)
    args = (xh, w1, b1, w2, b2, seg, offs, a)
    out = ops.fused_force_readout(e, *args)
    want = ref.fused_force_readout_ref(e, *args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    g_f = jax.grad(lambda ee, ww: jnp.sum(
        jnp.sin(ops.fused_force_readout(ee, xh, ww, b1, w2, b2, seg, offs,
                                        a))), argnums=(0, 1))(e, w1)
    g_r = jax.grad(lambda ee, ww: jnp.sum(
        jnp.sin(ref.fused_force_readout_ref(ee, xh, ww, b1, w2, b2, seg,
                                            offs, a))), argnums=(0, 1))(e, w1)
    for got, want in zip(g_f, g_r):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# property-based ragged sweep (optional dep, like the other hypothesis suites)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(
        num_segments=st.integers(1, 24),
        n_real=st.integers(0, 90),
        pad=st.integers(0, 40),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_fused_atom_conv_ragged_property(num_segments, n_real, pad, seed):
        rng = np.random.default_rng(seed)
        args = _atom_op_inputs(rng, num_segments, n_real + pad + 1, 16,
                               n_real)
        out = ops.fused_atom_conv(*args)
        want = ref.fused_atom_conv_ref(*args)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(
        num_bonds=st.integers(1, 30),
        n_real=st.integers(0, 70),
        pad=st.integers(0, 30),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_fused_bond_conv_ragged_property(num_bonds, n_real, pad, seed):
        rng = np.random.default_rng(seed)
        args = _bond_op_inputs(rng, 6, num_bonds, n_real + pad + 1, 16,
                               n_real)
        out = ops.fused_bond_conv(*args)
        want = ref.fused_bond_conv_ref(*args)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
except ImportError:  # pragma: no cover - bare envs skip the property sweep
    pass


# ---------------------------------------------------------------------------
# model level: conv_impl="fused" vs "unfused" on packed crystal batches
# ---------------------------------------------------------------------------

def _crystal(rng, n, **labels):
    return Crystal(lattice=np.eye(3) * 4.4 + rng.normal(0, .05, (3, 3)),
                   frac_coords=rng.random((n, 3)),
                   atomic_numbers=rng.integers(1, 60, n), **labels)


def _packed_batch(seed=0, sizes=(5, 7, 4), pad=(8, 32, 48)):
    rng = np.random.default_rng(seed)
    cs = [_crystal(rng, n, energy=float(rng.normal()),
                   forces=rng.normal(0, .1, (n, 3)),
                   stress=rng.normal(0, .1, (3, 3)),
                   magmoms=np.abs(rng.normal(0, 1, n))) for n in sizes]
    gs = [build_graph(c) for c in cs]
    caps = BatchCapacities(sum(sizes) + pad[0],
                           sum(g.num_bonds for g in gs) + pad[1],
                           sum(g.num_angles for g in gs) + pad[2])
    return batch_crystals(cs, gs, caps)


@pytest.mark.parametrize("variant", ["fast", "reference"])
def test_chgnet_fused_matches_unfused_forward(variant):
    """Acceptance: conv_impl="fused" matches "unfused" <= 1e-5 end-to-end."""
    batch = _packed_batch()
    params = chgnet_init(jax.random.PRNGKey(0), CHGNetConfig())
    want = chgnet_apply(
        params, CHGNetConfig(block_variant=variant, conv_impl="unfused"),
        batch)
    got = chgnet_apply(
        params, CHGNetConfig(block_variant=variant, conv_impl="fused"),
        batch)
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   atol=1e-5, err_msg=k)


def test_chgnet_fused_matches_unfused_gradient():
    """Acceptance: training gradients match <= 1e-5 through the fused path
    (chunked recompute backward vs autodiff-through-the-unfused-graph)."""
    batch = _packed_batch()
    params = chgnet_init(jax.random.PRNGKey(0), CHGNetConfig())

    def loss(p, conv):
        pred = chgnet_apply(params if p is None else p,
                            CHGNetConfig(conv_impl=conv), batch)
        return chgnet_loss(pred, batch, LossWeights())[0]

    g_u = jax.grad(lambda p: loss(p, "unfused"))(params)
    g_f = jax.grad(lambda p: loss(p, "fused"))(params)
    for path, got, want in zip(
            jax.tree_util.tree_flatten_with_path(g_f)[0],
            jax.tree.leaves(g_f), jax.tree.leaves(g_u)):
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5,
            err_msg=jax.tree_util.keystr(path[0]))


def test_autodiff_readout_composes_with_fused_convs():
    """Training through readout="autodiff" reverse-differentiates the
    custom-VJP backward itself — the chunk loops must stay scan-lowered
    (static trip count) for that second reverse pass to be legal."""
    batch = _packed_batch(sizes=(4,), pad=(4, 8, 8))
    cfg_u = CHGNetConfig(readout="autodiff", num_blocks=1,
                         conv_impl="unfused")
    cfg_f = cfg_u.with_(conv_impl="fused")
    params = chgnet_init(jax.random.PRNGKey(0), cfg_u)

    def loss(p, cfg):
        return chgnet_loss(chgnet_apply(p, cfg, batch), batch,
                           LossWeights())[0]

    g_u = jax.grad(lambda p: loss(p, cfg_u))(params)
    g_f = jax.grad(lambda p: loss(p, cfg_f))(params)
    for got, want in zip(jax.tree.leaves(g_f), jax.tree.leaves(g_u)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)


def test_unknown_conv_impl_raises():
    batch = _packed_batch()
    params = chgnet_init(jax.random.PRNGKey(0), CHGNetConfig())
    with pytest.raises(ValueError, match="conv impl"):
        chgnet_apply(params, CHGNetConfig(conv_impl="bogus"), batch)


# ---------------------------------------------------------------------------
# fused force readout: rotation equivariance (Eq. 8)
# ---------------------------------------------------------------------------

def _random_rotation(rng):
    q, r = np.linalg.qr(rng.normal(size=(3, 3)))
    q *= np.sign(np.diag(r))
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1
    return q


def test_fused_force_rotation_equivariance():
    """F(Rx) = R F(x) must survive the megakernel: n_ij stays scalar."""
    rng = np.random.default_rng(7)
    c = _crystal(rng, 5)
    rot = _random_rotation(rng)
    g = build_graph(c)
    caps = BatchCapacities(8, g.num_bonds + 4, g.num_angles + 4)
    cfg = CHGNetConfig(readout="direct", conv_impl="fused")
    params = chgnet_init(jax.random.PRNGKey(0), cfg)

    f1 = np.asarray(chgnet_apply(params, cfg,
                                 batch_crystals([c], [g], caps))["forces"])
    c2 = Crystal(lattice=c.lattice @ rot.T, frac_coords=c.frac_coords,
                 atomic_numbers=c.atomic_numbers)
    g2 = build_graph(c2)
    f2 = np.asarray(chgnet_apply(params, cfg,
                                 batch_crystals([c2], [g2], caps))["forces"])
    n = c.num_atoms
    np.testing.assert_allclose(f2[:n], f1[:n] @ rot.T, atol=2e-4)


# ---------------------------------------------------------------------------
# packed GatedMLP parameter layout: pack-once + legacy checkpoint migration
# ---------------------------------------------------------------------------

def test_pack_legacy_roundtrip():
    packed = gated_mlp_init(jax.random.PRNGKey(0), 96, 32)
    legacy = gated_mlp_legacy_template(packed)
    assert set(legacy.keys()) == {"wc", "bc", "wg", "bg", "ln_c_scale",
                                  "ln_c_bias", "ln_g_scale", "ln_g_bias"}
    repacked = pack_gated_mlp_params(legacy)
    for k in packed:
        np.testing.assert_array_equal(np.asarray(packed[k]),
                                      np.asarray(repacked[k]))


def test_trainer_restores_legacy_checkpoint(tmp_path):
    """A checkpoint written with the old separate-weight layout restores
    into the packed layout (packed once at load, DESIGN.md §3)."""
    pytest.importorskip("msgpack")
    from repro.runtime.checkpoint import save_checkpoint
    from repro.train.trainer import Trainer, TrainConfig

    trainer = Trainer(CHGNetConfig(), TrainConfig(), seed=0,
                      ckpt_dir=str(tmp_path))
    legacy_state = gated_mlp_legacy_template(
        jax.tree.map(lambda x: np.asarray(x) + 1.0, trainer.state()))
    save_checkpoint(str(tmp_path), 5, legacy_state)

    assert trainer.maybe_restore()
    assert trainer.step == 5
    want = pack_gated_mlp_params(legacy_state)["params"]
    for path, leaf in jax.tree_util.tree_flatten_with_path(want)[0]:
        got = trainer.params
        for k in path:
            got = got[k.key if hasattr(k, "key") else k.idx]
        np.testing.assert_array_equal(np.asarray(got), np.asarray(leaf),
                                      err_msg=jax.tree_util.keystr(path))
