"""Unified batching engine: capacity ladders, bucketed packing, compile
cache, non-divisible global batches, prefetcher error propagation."""
import numpy as np
import pytest

from repro.batching import (
    BatchCapacities,
    BatchingEngine,
    CapacityLadder,
    CompileCache,
    batch_crystals,
    capacity_for,
    ladder_for,
    ladder_from_stats,
    padding_waste,
    stack_device_batches,
)
from repro.core.neighbors import Crystal, build_graph
from repro.data import (
    BatchIterator, LoadBalanceSampler, Prefetcher, SyntheticConfig,
    make_dataset,
)


@pytest.fixture(scope="module")
def ds():
    return make_dataset(SyntheticConfig(num_crystals=64, max_atoms=32, seed=0))


# ---------------------------------------------------------------------------
# capacity ladder
# ---------------------------------------------------------------------------

def test_ladder_ascends_and_top_fits_dataset(ds):
    lad = ladder_for(ds, per_device_batch=4, num_buckets=4)
    totals = [b.total for b in lad.buckets]
    assert totals == sorted(totals) and len(set(totals)) == len(totals)
    # top bucket fits any 4 samples drawn from the dataset
    worst = sorted(ds.feature_counts())[-4:]
    na = 4 * max(c.num_atoms for c in ds.crystals)
    nb = 4 * max(g.num_bonds for g in ds.graphs)
    ng = 4 * max(g.num_angles for g in ds.graphs)
    assert lad.top.fits(na, nb, ng), (lad.top, worst)


def test_bucket_selection_never_truncates():
    """Property-style: any random size gets a bucket that fits (overflow
    buckets are synthesized for giants beyond the ladder top)."""
    rng = np.random.default_rng(0)
    lad = ladder_from_stats(
        rng.integers(2, 40, 200), rng.integers(10, 900, 200),
        rng.integers(0, 2000, 200), per_device_batch=4, num_buckets=3,
    )
    for _ in range(300):
        na = int(rng.integers(1, 10_000))
        nb = int(rng.integers(0, 100_000))
        ng = int(rng.integers(0, 200_000))
        b = lad.bucket_for(na, nb, ng)
        assert b.fits(na, nb, ng), (na, nb, ng, b)


def test_smallest_fitting_bucket_is_chosen():
    lad = CapacityLadder(buckets=(
        BatchCapacities(8, 64, 64),
        BatchCapacities(16, 128, 128),
        BatchCapacities(64, 512, 512),
    ))
    assert lad.bucket_for(4, 32, 10) == lad.buckets[0]
    assert lad.bucket_for(9, 32, 10) == lad.buckets[1]
    assert lad.bucket_for(60, 500, 500) == lad.buckets[2]


def test_capacity_for_is_aligned_and_sufficient(ds):
    caps = capacity_for(ds, per_device_batch=8)
    assert caps.atoms % 256 == 0 and caps.bonds % 256 == 0
    assert caps.atoms >= 8 and caps.bonds > 0


# ---------------------------------------------------------------------------
# packing with crystal slots
# ---------------------------------------------------------------------------

def _toy_crystals(ns, seed=0):
    rng = np.random.default_rng(seed)
    cs = [Crystal(lattice=np.eye(3) * 4.0, frac_coords=rng.random((n, 3)),
                  atomic_numbers=rng.integers(1, 10, n)) for n in ns]
    return cs, [build_graph(c) for c in cs]


def test_crystal_slot_padding_and_stacking():
    cs, gs = _toy_crystals([3, 5, 4])
    caps = BatchCapacities(
        atoms=32, bonds=sum(g.num_bonds for g in gs) + 8,
        angles=sum(g.num_angles for g in gs) + 8)
    # shards of unequal length pack to the same shapes via crystal slots
    b1 = batch_crystals(cs[:2], gs[:2], caps, num_crystal_slots=3)
    b2 = batch_crystals(cs[2:], gs[2:], caps, num_crystal_slots=3)
    stacked = stack_device_batches([b1, b2])
    assert stacked.lattice.shape == (2, 3, 3, 3)
    assert float(np.asarray(stacked.crystal_mask).sum()) == 3
    # padded crystal slots keep identity lattices (det != 0)
    assert np.allclose(np.asarray(b2.lattice)[1:], np.eye(3))
    with pytest.raises(ValueError):
        batch_crystals(cs, gs, caps, num_crystal_slots=2)


def test_stack_rejects_mismatched_shapes():
    cs, gs = _toy_crystals([3, 3])
    caps = BatchCapacities(16, 512, 2048)
    b1 = batch_crystals(cs[:1], gs[:1], caps, num_crystal_slots=1)
    b2 = batch_crystals(cs[1:], gs[1:], caps, num_crystal_slots=2)
    with pytest.raises(ValueError, match="disagree"):
        stack_device_batches([b1, b2])


# ---------------------------------------------------------------------------
# non-divisible global batches (regression)
# ---------------------------------------------------------------------------

def test_load_balance_sampler_distributes_remainder(ds):
    counts = ds.feature_counts()
    lb = LoadBalanceSampler(counts, 0)
    idx = np.arange(10)
    shards = lb.assign(idx, num_devices=4)
    assert sorted(len(s) for s in shards) == [2, 2, 3, 3]
    np.testing.assert_array_equal(
        np.sort(np.concatenate(shards)), idx)
    # regression: no device may end up with an all-padding (empty) shard
    for b, d in [(5, 4), (7, 3), (9, 8), (6, 6)]:
        lens = sorted(len(s) for s in lb.assign(np.arange(b), d))
        assert lens[0] >= 1 and lens[-1] - lens[0] <= 1, (b, d, lens)


def test_batch_iterator_non_divisible_batch_stacks(ds):
    caps = capacity_for(ds, per_device_batch=3)
    it = BatchIterator(ds, global_batch=10, num_devices=4, caps=caps)
    batch = next(iter(it))
    assert batch.lattice.shape == (4, 3, 3, 3)  # ceil(10/4) = 3 slots each
    # no sample dropped: 10 real crystals across the 4 shards
    assert float(np.asarray(batch.crystal_mask).sum()) == 10


def test_batch_iterator_with_ladder(ds):
    lad = ladder_for(ds, per_device_batch=4, num_buckets=3)
    it = BatchIterator(ds, global_batch=8, num_devices=2, caps=lad)
    seen = set()
    for i, batch in enumerate(it):
        assert float(np.asarray(batch.crystal_mask).sum()) == 8
        seen.add(batch.atom_z.shape)
        if i >= 3:
            break
    assert len(seen) >= 1  # bucketed shapes, all packed without error


def test_batch_iterator_validates_args(ds):
    caps = capacity_for(ds, 4)
    with pytest.raises(ValueError):
        BatchIterator(ds, global_batch=2, num_devices=4, caps=caps)


# ---------------------------------------------------------------------------
# compile cache + engine stats
# ---------------------------------------------------------------------------

def test_compile_cache_hits_and_misses():
    cache = CompileCache()
    calls = []

    def build():
        calls.append(1)
        return lambda x: x + 1

    key = ("step", BatchCapacities(8, 64, 64), 2, "cfg")
    f1 = cache.get(key, build)
    f2 = cache.get(key, build)
    assert f1 is f2 and len(calls) == 1
    assert cache.hits == 1 and cache.misses == 1
    cache.get(("other",), build)
    assert len(cache) == 2 and len(calls) == 2


def test_engine_packs_and_tracks_waste():
    cs, gs = _toy_crystals([4, 6])
    lad = CapacityLadder(buckets=(
        BatchCapacities(16, 1024, 4096), BatchCapacities(32, 4096, 16384)))
    eng = BatchingEngine(lad, CompileCache())
    batch, bucket = eng.pack(cs, gs)
    assert bucket in lad.buckets
    assert 0.0 < eng.mean_padding_waste < 1.0
    assert eng.stats()["batches_packed"] == 1
    assert abs(padding_waste(batch) - eng.mean_padding_waste) < 1e-12


# ---------------------------------------------------------------------------
# prefetcher error propagation (regression: was silently truncating)
# ---------------------------------------------------------------------------

def test_prefetcher_reraises_worker_exception():
    def gen():
        yield 1
        yield 2
        raise RuntimeError("bad batch")

    pf = Prefetcher(gen(), depth=1)
    got = []
    with pytest.raises(RuntimeError, match="bad batch"):
        for x in pf:
            got.append(x)
    assert got == [1, 2]  # items before the failure still delivered
