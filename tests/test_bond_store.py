"""Undirected bond store (DESIGN.md §5): mirror-map construction
(hypothesis ragged sweep incl. self-image bonds and capped fallback),
pack/validate mirror invariant, undirected==directed forward+gradient
equivalence across the mlp x agg x conv tiers and both readouts,
rotation/translation equivariance under the undirected store, Verlet
serve canonicalization, and the mlp_impl="pallas" training smoke
(previously forward-only).  All run on CPU via REPRO_KERNELS_INTERPRET=1.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.batching import BatchCapacities, batch_crystals
from repro.batching.pack import validate_layout
from repro.core.chgnet import CHGNetConfig, chgnet_apply, chgnet_init
from repro.core.losses import LossWeights, chgnet_loss
from repro.core.neighbors import (
    Crystal,
    VerletNeighborList,
    build_graph,
    build_mirror_maps,
)


def _crystal(rng, n, labels=True, scale=4.0):
    kw = {}
    if labels:
        kw = dict(energy=float(rng.normal()),
                  forces=rng.normal(0, .1, (n, 3)),
                  stress=rng.normal(0, .1, (3, 3)),
                  magmoms=np.abs(rng.normal(0, 1, n)))
    return Crystal(
        lattice=np.eye(3) * scale + rng.normal(0, .05, (3, 3)),
        frac_coords=rng.random((n, 3)),
        atomic_numbers=rng.integers(1, 60, n),
        **kw,
    )


def _batch(rng, sizes=(5, 7, 4), **kw):
    cs = [_crystal(rng, n, **kw) for n in sizes]
    gs = [build_graph(c) for c in cs]
    caps = BatchCapacities(sum(sizes) + 8,
                           sum(g.num_bonds for g in gs) + 16,
                           sum(g.num_angles for g in gs) + 16)
    return batch_crystals(cs, gs, caps)


@pytest.fixture(scope="module")
def batch():
    return _batch(np.random.default_rng(0))


@pytest.fixture(scope="module")
def params():
    return chgnet_init(jax.random.PRNGKey(0), CHGNetConfig(),
                       dtype=jnp.float32)


# ---------------------------------------------------------------------------
# mirror-map construction
# ---------------------------------------------------------------------------

def _check_maps(bc, bn, bi, pair, sign, rep):
    """The §5 construction invariants, asserted directly on the maps."""
    e = bc.shape[0]
    nu = rep.shape[0]
    assert pair.shape == (e,) and sign.shape == (e,)
    if e == 0:
        assert nu == 0
        return
    # representatives strictly increase and are canonically oriented
    assert np.all(np.diff(rep) > 0) if nu > 1 else True
    assert np.all(sign[rep] == 1.0)
    # each undirected id: exactly one +1, at most one -1 reference
    assert np.all(np.bincount(pair[sign > 0], minlength=nu) == 1)
    assert np.all(np.bincount(pair[sign < 0], minlength=nu) <= 1)
    # orientation reconstruction is exact
    r = rep[pair]
    plus = sign > 0
    same = (bc == bc[r]) & (bn == bn[r]) & np.all(bi == bi[r], axis=1)
    flip = (bc == bn[r]) & (bn == bc[r]) & np.all(bi == -bi[r], axis=1)
    assert np.all(same[plus])
    assert np.all(flip[~plus])


def test_mirror_maps_symmetric_graph_halves():
    rng = np.random.default_rng(1)
    for i in range(5):
        c = _crystal(rng, int(rng.integers(2, 9)), labels=False)
        g = build_graph(c)
        assert g.bond_pair is not None
        assert 2 * g.num_undirected == g.num_bonds  # exact pair symmetry
        _check_maps(g.bond_center, g.bond_nbr, g.bond_image,
                    g.bond_pair, g.bond_sign, g.und_rep)


def test_mirror_maps_self_image_bonds():
    """A 1-atom crystal: every bond is i-i-n with its i-i-(-n) mirror —
    canonicalization must pair them on the image alone."""
    c = Crystal(lattice=np.eye(3) * 3.0, frac_coords=np.zeros((1, 3)),
                atomic_numbers=np.array([8]))
    g = build_graph(c)
    assert g.num_bonds > 0
    assert np.all(g.bond_center == g.bond_nbr)  # all self-image
    assert 2 * g.num_undirected == g.num_bonds
    _check_maps(g.bond_center, g.bond_nbr, g.bond_image,
                g.bond_pair, g.bond_sign, g.und_rep)


def test_mirror_maps_capped_asymmetry_falls_back():
    """cap_mode="per_center" keeps the closest neighbors per CENTER, which
    can drop one direction of a pair — unmatched bonds must become
    singleton undirected entries (sign +1, own orientation), keeping the
    maps exact.  (The default cap_mode="symmetric" never breaks symmetry;
    see test_symmetric_cap_preserves_pair_symmetry.)
    """
    rng = np.random.default_rng(7)
    found_asym = False
    for i in range(12):
        c = _crystal(rng, int(rng.integers(4, 10)), labels=False)
        g = build_graph(c, max_nbr_per_atom=3, cap_mode="per_center")
        _check_maps(g.bond_center, g.bond_nbr, g.bond_image,
                    g.bond_pair, g.bond_sign, g.und_rep)
        assert g.num_bonds / 2 <= g.num_undirected <= g.num_bonds
        if 2 * g.num_undirected != g.num_bonds:
            found_asym = True
            # singletons are exactly the ids with no -1 reference
            refs_minus = np.bincount(g.bond_pair[g.bond_sign < 0],
                                     minlength=g.num_undirected)
            assert np.sum(refs_minus == 0) \
                == 2 * g.num_undirected - g.num_bonds
    assert found_asym, "cap never broke symmetry; weak test inputs"


def test_symmetric_cap_preserves_pair_symmetry():
    """Default cap_mode="symmetric" (DESIGN.md §6): a pair survives
    max_nbr_per_atom iff both directions do — Eu == E/2 exactly, packing
    needs no und_bonds override, and the kept set is a subset of the
    per-center cap's (degree can undershoot, never overshoot)."""
    rng = np.random.default_rng(7)
    checked_pack = False
    for i in range(8):
        c = _crystal(rng, int(rng.integers(4, 10)), labels=False)
        g = build_graph(c, max_nbr_per_atom=3)
        _check_maps(g.bond_center, g.bond_nbr, g.bond_image,
                    g.bond_pair, g.bond_sign, g.und_rep)
        assert 2 * g.num_undirected == g.num_bonds
        # every directed bond's mirror is present
        fwd = {(int(a), int(b), *map(int, n))
               for a, b, n in zip(g.bond_center, g.bond_nbr, g.bond_image)}
        assert all((b, a, *[-x for x in n]) in fwd for a, b, *n in
                   ((t[0], t[1], *t[2:]) for t in fwd))
        # subset of the per-center keep, and degree never above the cap
        gp = build_graph(c, max_nbr_per_atom=3, cap_mode="per_center")
        assert g.num_bonds <= gp.num_bonds
        assert np.bincount(g.bond_center).max(initial=0) <= 3
        if g.num_bonds and not checked_pack:
            # default bonds//2-derived und capacity fits (no override)
            caps = BatchCapacities(16, g.num_bonds, g.num_angles + 4)
            validate_layout(batch_crystals([c], [g], caps))
            checked_pack = True
    assert checked_pack


def test_capped_asymmetric_pack_needs_und_override():
    """Eu > bonds//2 after per-center capping: default caps raise with a
    pointed message; an explicit und_bonds override packs and validates."""
    rng = np.random.default_rng(11)
    cs, gs = [], []
    for _ in range(6):
        c = _crystal(rng, 8, labels=False)
        g = build_graph(c, max_nbr_per_atom=3, cap_mode="per_center")
        if 2 * g.num_undirected != g.num_bonds:
            cs.append(c)
            gs.append(g)
    assert cs, "no asymmetric graphs generated"
    bonds = sum(g.num_bonds for g in gs)
    angles = sum(g.num_angles for g in gs)
    und = sum(g.num_undirected for g in gs)
    tight = BatchCapacities(8 * len(cs), bonds, angles)
    if und > tight.und_cap:
        with pytest.raises(ValueError, match="und_bonds"):
            batch_crystals(cs, gs, tight)
    roomy = BatchCapacities(8 * len(cs), bonds, angles, und_bonds=und + 4)
    validate_layout(batch_crystals(cs, gs, roomy))


def test_pack_validates_mirror_invariant(batch):
    validate_layout(batch)
    # corrupting one sign must be caught
    import dataclasses
    bad = dataclasses.replace(
        batch, bond_sign=batch.bond_sign.at[0].set(-batch.bond_sign[0]))
    with pytest.raises(ValueError, match="mirror|sign"):
        validate_layout(bad)


def test_hand_built_graph_without_maps_is_repaired():
    """GraphIndices with bond_pair=None (hand-built): packing must build
    the maps via build_mirror_maps and still certify the invariant."""
    import dataclasses as dc

    rng = np.random.default_rng(3)
    c = _crystal(rng, 5, labels=False)
    g = build_graph(c)
    bare = dc.replace(g, bond_pair=None, bond_sign=None, und_rep=None)
    caps = BatchCapacities(8, g.num_bonds + 4, g.num_angles + 4)
    validate_layout(batch_crystals([c], [bare], caps))


try:
    import hypothesis  # noqa: F401

    HAVE_HYP = True
except ImportError:
    HAVE_HYP = False

if HAVE_HYP:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 9),
           st.sampled_from([None, "symmetric", "per_center"]))
    def test_mirror_maps_hypothesis_sweep(seed, n, cap_mode):
        """Ragged sweep over random cells: odd image vectors (skewed tiny
        cells), self-image bonds (n=1), and both cap modes all keep the
        maps total and exact."""
        rng = np.random.default_rng(seed)
        lat = np.eye(3) * rng.uniform(2.2, 6.0) \
            + rng.normal(0, 0.3, (3, 3))
        if abs(np.linalg.det(lat)) < 1.0:
            lat += np.eye(3) * 2.0
        c = Crystal(lattice=lat, frac_coords=rng.random((n, 3)),
                    atomic_numbers=rng.integers(1, 90, n))
        g = build_graph(c, max_nbr_per_atom=None if cap_mode is None else 4,
                        cap_mode=cap_mode or "symmetric")
        _check_maps(g.bond_center, g.bond_nbr, g.bond_image,
                    g.bond_pair, g.bond_sign, g.und_rep)
        if cap_mode != "per_center":
            # uncapped AND symmetric-capped graphs are pair-symmetric
            assert 2 * g.num_undirected == g.num_bonds
        # expansion through the maps reproduces every directed bond's
        # geometry exactly (the property the model relies on)
        cart = c.cart_coords()
        vec_d = cart[g.bond_nbr] + g.bond_image @ lat - cart[g.bond_center]
        rep = g.und_rep
        vec_u = cart[g.bond_nbr[rep]] + g.bond_image[rep] @ lat \
            - cart[g.bond_center[rep]]
        np.testing.assert_allclose(
            g.bond_sign[:, None] * vec_u[g.bond_pair], vec_d,
            rtol=1e-9, atol=1e-9)


# ---------------------------------------------------------------------------
# model equivalence: undirected == directed per tier, fwd + grad
# ---------------------------------------------------------------------------

# the §2/§3 matrix corners (same set as tests/test_precision.py)
TIERS = [
    ("packed", "scatter", "unfused"),
    ("ref", "sorted", "unfused"),
    ("packed", "matmul", "unfused"),
    ("pallas", "pallas", "unfused"),
    ("packed", "scatter", "fused"),
    ("packed", "pallas", "fused"),
]


def _assert_close(got, want, atol, msg):
    # tolerance scaled to the tensor's magnitude (stress entries reach
    # O(100) eV-scale units at these random scales; 1e-5 is then relative)
    scale = max(1.0, float(np.max(np.abs(want))))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=atol * scale, err_msg=msg)


@pytest.mark.parametrize("mlp_impl,agg_impl,conv_impl", TIERS)
def test_undirected_matches_directed_forward(batch, params, mlp_impl,
                                             agg_impl, conv_impl):
    cfg = CHGNetConfig(readout="direct", mlp_impl=mlp_impl,
                       agg_impl=agg_impl, conv_impl=conv_impl)
    want = chgnet_apply(params, cfg, batch)
    got = chgnet_apply(params, cfg.with_(bond_store="undirected"), batch)
    for k in want:
        _assert_close(got[k], want[k], 1e-5,
                      f"{k} {mlp_impl}/{agg_impl}/{conv_impl}")


@pytest.mark.parametrize("mlp_impl,agg_impl,conv_impl", TIERS)
def test_undirected_matches_directed_gradients(batch, params, mlp_impl,
                                               agg_impl, conv_impl):
    cfg = CHGNetConfig(readout="direct", mlp_impl=mlp_impl,
                       agg_impl=agg_impl, conv_impl=conv_impl)

    def loss(p, c):
        return chgnet_loss(chgnet_apply(p, c, batch), batch,
                           LossWeights())[0]

    g_d = jax.jit(jax.grad(loss), static_argnums=1)(params, cfg)
    g_u = jax.jit(jax.grad(loss), static_argnums=1)(
        params, cfg.with_(bond_store="undirected"))
    for (path, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(g_d)[0][:999],
            jax.tree_util.tree_flatten_with_path(g_u)[0]):
        _assert_close(b, a, 1e-5,
                      f"{jax.tree_util.keystr(path)} "
                      f"{mlp_impl}/{agg_impl}/{conv_impl}")


def test_undirected_matches_directed_autodiff_readout(batch, params):
    """The second-order path: forces/stress differentiate through the
    Eu geometry.  Mirrored vectors differ by one f32 ulp, so stress is
    compared relative to its scale (DESIGN.md §5 tolerances)."""
    cfg = CHGNetConfig(readout="autodiff")
    want = chgnet_apply(params, cfg, batch)
    got = chgnet_apply(params, cfg.with_(bond_store="undirected"), batch)
    for k in want:
        _assert_close(got[k], want[k], 1e-5, f"autodiff/{k}")


@pytest.mark.parametrize("precision", ["mixed", "bf16"])
def test_undirected_tracks_directed_under_low_precision(batch, params,
                                                        precision):
    """bf16 rounding can flip on the 1-ulp mirrored-vector difference, so
    low-precision stores are compared at the §4 cross-policy tolerance."""
    cfg = CHGNetConfig(readout="direct", precision=precision)
    want = chgnet_apply(params, cfg, batch)
    got = chgnet_apply(params, cfg.with_(bond_store="undirected"), batch)
    for k in want:
        _assert_close(got[k], want[k], 3e-2, f"{precision}/{k}")


def test_undirected_serve_engine_end_to_end():
    """ServeEngine + BatchedMD run the undirected store through the Verlet
    update path: every per-step graph re-canonicalizes its mirror maps and
    the packed batches keep certifying the invariant."""
    from repro.serve import BatchedMD, ServeEngine

    rng = np.random.default_rng(5)
    crystals = [_crystal(rng, n, labels=False) for n in (4, 5)]
    cfg = CHGNetConfig(readout="direct", bond_store="undirected")
    params = chgnet_init(jax.random.PRNGKey(1), cfg)
    serve = ServeEngine.for_structures(params, cfg, crystals,
                                      validate_layout=True)
    md = BatchedMD(serve, crystals, dt=1e-3)
    out = md.step(3)
    assert md.steps_done == 3
    for f in out["forces"]:
        assert np.all(np.isfinite(f))
    # the Verlet refilter preserves pair symmetry exactly
    for r in md.replicas:
        g = r.nlist.update(r.crystal)
        assert 2 * g.num_undirected == g.num_bonds
        _check_maps(g.bond_center, g.bond_nbr, g.bond_image,
                    g.bond_pair, g.bond_sign, g.und_rep)


def test_verlet_update_preserves_canonicalization_under_drift():
    """Moving atoms (wrapped coords, shifted images) must not break the
    mirror maps: update() rebuilds them from the refiltered pairs."""
    rng = np.random.default_rng(9)
    c = _crystal(rng, 6, labels=False)
    nlist = VerletNeighborList(c, skin=0.4)
    for step in range(5):
        cart = c.cart_coords() + rng.normal(0, 0.05, (6, 3))
        c.frac_coords = (cart @ np.linalg.inv(c.lattice)) % 1.0
        g = nlist.update(c)
        fresh = build_graph(c)
        assert g.num_bonds == fresh.num_bonds
        assert 2 * g.num_undirected == g.num_bonds
        _check_maps(g.bond_center, g.bond_nbr, g.bond_image,
                    g.bond_pair, g.bond_sign, g.und_rep)


# ---------------------------------------------------------------------------
# equivariance under the undirected store
# ---------------------------------------------------------------------------

def _rotation(rng):
    q, r = np.linalg.qr(rng.normal(size=(3, 3)))
    q *= np.sign(np.diag(r))
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1
    return q


@pytest.mark.parametrize("readout", ["direct", "autodiff"])
def test_undirected_forces_rotation_equivariant(readout):
    rng = np.random.default_rng(13)
    c = _crystal(rng, 5, labels=False)
    rot = _rotation(rng)
    g = build_graph(c)
    caps = BatchCapacities(8, g.num_bonds + 4, g.num_angles + 4)
    cfg = CHGNetConfig(readout=readout, bond_store="undirected")
    params = chgnet_init(jax.random.PRNGKey(0), cfg)
    f1 = np.asarray(chgnet_apply(params, cfg,
                                 batch_crystals([c], [g], caps))["forces"])
    c2 = Crystal(lattice=c.lattice @ rot.T, frac_coords=c.frac_coords,
                 atomic_numbers=c.atomic_numbers)
    g2 = build_graph(c2)
    assert g2.num_bonds == g.num_bonds
    f2 = np.asarray(chgnet_apply(params, cfg,
                                 batch_crystals([c2], [g2], caps))["forces"])
    n = c.num_atoms
    np.testing.assert_allclose(f2[:n], f1[:n] @ rot.T, atol=2e-4)


def test_undirected_translation_invariance():
    """Rigid translation (frac shift mod 1): energy invariant, forces
    equivariant (unchanged) under the undirected store."""
    rng = np.random.default_rng(17)
    c = _crystal(rng, 5, labels=False)
    g = build_graph(c)
    caps = BatchCapacities(8, g.num_bonds + 4, g.num_angles + 4)
    cfg = CHGNetConfig(readout="direct", bond_store="undirected")
    params = chgnet_init(jax.random.PRNGKey(0), cfg)
    out1 = chgnet_apply(params, cfg, batch_crystals([c], [g], caps))
    shift = rng.random(3)
    c2 = Crystal(lattice=c.lattice,
                 frac_coords=(c.frac_coords + shift) % 1.0,
                 atomic_numbers=c.atomic_numbers)
    g2 = build_graph(c2)
    assert g2.num_bonds == g.num_bonds
    out2 = chgnet_apply(params, cfg, batch_crystals([c2], [g2], caps))
    np.testing.assert_allclose(np.asarray(out2["energy"]),
                               np.asarray(out1["energy"]), atol=1e-4)
    n = c.num_atoms
    np.testing.assert_allclose(np.asarray(out2["forces"])[:n],
                               np.asarray(out1["forces"])[:n], atol=1e-4)


# ---------------------------------------------------------------------------
# training: pallas tier loss-descent smoke (previously forward-only) and
# undirected-store trainability
# ---------------------------------------------------------------------------

def _descends(cfg, steps=6):
    from repro.optim.adam import adam_init
    from repro.train.trainer import TrainConfig, make_chgnet_step_fns

    rng = np.random.default_rng(23)
    batch = _batch(rng, sizes=(5, 6))
    params = chgnet_init(jax.random.PRNGKey(0), cfg)
    opt = adam_init(params)
    train, _, _ = make_chgnet_step_fns(
        cfg, TrainConfig(global_batch=2, total_steps=steps, lr_k=1))
    losses = []
    for s in range(steps):
        params, opt, m = train(params, opt, batch, jnp.asarray(s))
        losses.append(float(m["loss"]))
    assert np.all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0], losses
    return losses


def test_pallas_mlp_training_descends():
    """mlp_impl="pallas" trains: fused_rbf / fused_fourier /
    fused_gated_mlp_packed now carry custom VJPs (previously
    forward-only, DESIGN.md §4)."""
    _descends(CHGNetConfig(readout="direct", mlp_impl="pallas"))


def test_undirected_pallas_training_descends():
    """The full stack: undirected store + pallas MLP tier trains."""
    _descends(CHGNetConfig(readout="direct", mlp_impl="pallas",
                           bond_store="undirected"))
