"""Angle-pair dedup store (DESIGN.md §5 extension).

Under ``bond_store="undirected"`` the ordered angle list carries both
(ij, ik) and (ik, ij) per center; the angle cosine is bitwise symmetric
under the swap, so geometry/Fourier/angle-embed run once per unordered
pair (Au == Na/2) and expand through ``angle_pair``.  Pins:

  - map construction (Au == Na/2 on symmetric lists, singleton fallback,
    representative orientation);
  - EXACT (0 ulp) equality of the expanded cosines vs the directed rows;
  - the ``validate_layout`` mirror invariant rejects tampered maps;
  - directed == undirected model forward/grad stays within tolerance
    with the dedup rows active (it is on by default for the undirected
    store, so test_bond_store.py covers the sweep; here we pin the
    dedup-specific pieces).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.batching import BatchCapacities, batch_crystals
from repro.batching.pack import validate_layout
from repro.core.basis import compute_geometry, compute_geometry_undirected
from repro.core.neighbors import (
    Crystal,
    build_angle_mirror_maps,
    build_graph,
)


def _crystal(rng, n, scale=3.6):
    return Crystal(
        lattice=np.eye(3) * scale + rng.normal(0, .05, (3, 3)),
        frac_coords=rng.random((n, 3)),
        atomic_numbers=rng.integers(1, 60, n),
    )


@pytest.fixture(scope="module")
def packed():
    rng = np.random.default_rng(7)
    cs = [_crystal(rng, 5), _crystal(rng, 6), _crystal(rng, 4)]
    gs = [build_graph(c) for c in cs]
    caps = BatchCapacities(sum(c.num_atoms for c in cs) + 4,
                           sum(g.num_bonds for g in gs) + 8,
                           sum(g.num_angles for g in gs) + 8)
    return batch_crystals(cs, gs, caps), gs


def test_map_construction_halves_symmetric_lists():
    rng = np.random.default_rng(0)
    for _ in range(20):
        g = build_graph(_crystal(rng, int(rng.integers(3, 9))))
        if g.num_angles == 0:
            continue
        assert g.angle_pair is not None and g.und_angle_rep is not None
        na, nu = g.num_angles, g.und_angle_rep.shape[0]
        # build_graph emits all ordered pairs -> exact halving
        assert na == 2 * nu
        # each und id referenced exactly twice, reps map back to members
        counts = np.bincount(g.angle_pair, minlength=nu)
        assert np.all(counts == 2)
        rep = g.und_angle_rep
        assert np.all(g.angle_pair[rep] == np.arange(nu))
        # representative + mirror carry the same unordered bond pair
        lo = np.minimum(g.angle_ij, g.angle_ik)
        hi = np.maximum(g.angle_ij, g.angle_ik)
        key = lo.astype(np.int64) << 32 | hi
        for u in range(nu):
            members = np.where(g.angle_pair == u)[0]
            assert len(set(key[members])) == 1


def test_singleton_fallback_total():
    """Asymmetric hand-built angle lists still get total maps."""
    ij = np.array([0, 1, 3], np.int32)
    ik = np.array([1, 0, 4], np.int32)  # {0,1} paired, {3,4} singleton
    pair, rep = build_angle_mirror_maps(ij, ik)
    assert rep.shape[0] == 2
    assert pair[0] == pair[1] != pair[2]
    assert np.all(pair[rep] == np.arange(2))
    p0, r0 = build_angle_mirror_maps(ij[:0], ik[:0])
    assert p0.shape == (0,) and r0.shape == (0,)


def test_dedup_rows_expand_exactly(packed):
    """cos/theta at the dedup rows expand to the directed rows bitwise."""
    batch, _ = packed
    *_, cos_d, theta_d = compute_geometry_undirected(
        batch, angle_rows="directed")
    *_, cos_u, theta_u = compute_geometry_undirected(
        batch, angle_rows="undirected")
    mask = np.asarray(batch.angle_mask) > 0
    pair = np.asarray(batch.angle_pair)
    assert np.array_equal(np.asarray(cos_u)[pair][mask],
                          np.asarray(cos_d)[mask])
    assert np.array_equal(np.asarray(theta_u)[pair][mask],
                          np.asarray(theta_d)[mask])
    # and the directed store agrees up to float assoc. (sanity)
    *_, cos_ref, _ = compute_geometry(batch)
    np.testing.assert_allclose(np.asarray(cos_d)[mask],
                               np.asarray(cos_ref)[mask], atol=1e-6)


def test_validate_layout_rejects_tampered_angle_maps(packed):
    batch, _ = packed
    validate_layout(batch)  # clean batch passes

    na = int(np.asarray(batch.angle_mask).sum())
    if na < 2:
        pytest.skip("batch too small to tamper")
    # point a real angle at the wrong und entry
    ap = np.asarray(batch.angle_pair).copy()
    u0, u1 = ap[0], ap[1]
    if u0 == u1:
        pytest.skip("first two angles share a pair")
    ap[0] = u1
    import dataclasses
    bad = dataclasses.replace(batch, angle_pair=jnp.asarray(ap))
    with pytest.raises(ValueError):
        validate_layout(bad)
    # orientation mismatch: und entry referencing unrelated bonds
    uij = np.asarray(batch.und_angle_ij).copy()
    uik = np.asarray(batch.und_angle_ik).copy()
    uij[u0], uik[u0] = uik[u0], uij[u0] + 1
    bad2 = dataclasses.replace(batch, und_angle_ij=jnp.asarray(uij),
                               und_angle_ik=jnp.asarray(uik))
    with pytest.raises(ValueError):
        validate_layout(bad2)


def test_capacity_overflow_carries_und_angles():
    caps = BatchCapacities(64, 256, 512, und_angles=300)
    assert caps.und_angle_cap == 300
    k = caps.scaled(2)
    assert k.und_angle_cap == 600
    assert caps.fits(10, 20, 30, n_und_angles=299)
    assert not caps.fits(10, 20, 30, n_und_angles=301)
