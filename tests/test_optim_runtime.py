"""Optimizer, schedules (Eq. 14), checkpointing, elasticity, fault runtime."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    AdamConfig, adam_init, adam_update, clip_by_global_norm,
    compress, cosine_annealing, decompress, ef_init, global_norm,
    scaled_init_lr,
)
from repro.runtime import (
    FaultInjector, StragglerWatch, latest_step, restore_checkpoint,
    run_with_restarts, save_checkpoint,
)


# ------------------------------- optim -------------------------------------

def test_adam_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adam_init(params)
    for _ in range(400):
        grads = jax.tree.map(lambda w: 2 * w, params)
        params, state = adam_update(grads, state, params, 0.05)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_decays_weights():
    params = {"w": jnp.ones((4,))}
    cfg = AdamConfig(weight_decay=0.1)
    state = adam_init(params)
    grads = {"w": jnp.zeros((4,))}
    p2, _ = adam_update(grads, state, params, 0.1, cfg)
    assert float(p2["w"][0]) < 1.0


def test_scaled_lr_eq14():
    """Paper Eq. 14: init_LR = batch/k * 3e-4, k = 128."""
    assert scaled_init_lr(128) == pytest.approx(3e-4)
    assert scaled_init_lr(2048) == pytest.approx(2048 / 128 * 3e-4)


def test_cosine_annealing_shape():
    lr0 = float(cosine_annealing(0, 100, 1.0))
    lr_mid = float(cosine_annealing(50, 100, 1.0))
    lr_end = float(cosine_annealing(100, 100, 1.0))
    assert lr0 == pytest.approx(1.0)
    assert lr_mid == pytest.approx(0.5, abs=1e-6)
    assert lr_end == pytest.approx(0.0, abs=1e-6)
    # warmup ramps from 0
    lw = float(cosine_annealing(1, 100, 1.0, warmup_steps=10))
    assert lw == pytest.approx(0.1, abs=1e-6)


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((4,)) * 10}
    clipped = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    small = {"a": jnp.ones((4,)) * 1e-3}
    same = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(same["a"]), 1e-3, rtol=1e-5)


def test_compression_error_feedback_reduces_bias():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1e-3, 1000),
                          jnp.float32)}
    ef = ef_init(g)
    total_q = jnp.zeros_like(g["w"])
    total = jnp.zeros_like(g["w"])
    for _ in range(50):
        q, ef = compress(g, ef)
        total_q = total_q + decompress(q)["w"]
        total = total + g["w"]
    # with error feedback, accumulated quantized sum tracks the true sum
    assert float(jnp.abs(total_q - total).max()) < 2e-5


# ----------------------------- checkpoint ----------------------------------

def test_checkpoint_roundtrip_and_keep(tmp_path):
    d = str(tmp_path)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray(3, jnp.int32)}}
    for step in (10, 20, 30, 40):
        save_checkpoint(d, step, tree, keep=2)
    assert latest_step(d) == 40
    got, step, _meta = restore_checkpoint(d, tree)
    assert step == 40
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
    # keep=2 pruning
    from repro.runtime import list_checkpoints
    assert list_checkpoints(d) == [30, 40]
    # no stray tmp files (atomicity)
    assert not [f for f in os.listdir(d) if f.endswith(".tmp")]


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, {"a": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(d, {"a": jnp.zeros((3, 3))})


def test_elastic_reshard_single_device(tmp_path):
    from jax.sharding import PartitionSpec as P

    from repro.runtime import elastic_restore

    d = str(tmp_path)
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    save_checkpoint(d, 5, tree)
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    got, step, _ = elastic_restore(d, tree, mesh, lambda path, leaf: P())
    assert step == 5
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))


# ------------------------------- fault -------------------------------------

def test_straggler_watch_flags_slow_steps():
    w = StragglerWatch(window=16, threshold=2.0)
    for _ in range(10):
        w.record(0.1)
    assert w.record(0.5) is True
    assert w.flags == 1


def test_run_with_restarts_recovers():
    calls = {"n": 0, "resume": 0}

    def loop(start):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("boom")
        return f"done from {start}"

    def resume():
        calls["resume"] += 1
        return calls["n"] * 100

    out = run_with_restarts(loop, resume_step_fn=resume, max_restarts=5)
    assert out.startswith("done")
    assert calls["n"] == 3


def test_run_with_restarts_gives_up():
    def loop(start):
        raise RuntimeError("always")

    with pytest.raises(RuntimeError):
        run_with_restarts(loop, resume_step_fn=lambda: 0, max_restarts=2)


def test_fault_injector_fires_once():
    fi = FaultInjector({3})
    fi.maybe_fail(2)
    with pytest.raises(RuntimeError):
        fi.maybe_fail(3)
    fi.maybe_fail(3)  # second time: no fire
