"""Buffer donation, with aliasing asserted (DESIGN.md §6/§8 hygiene).

Every step builder exposes a ``donate`` flag that rides its compile-cache
key; these tests pin the actual aliasing behaviour rather than just the
flag plumbing:

  - donated arguments are CONSUMED: their buffers are deleted after the
    call (``.is_deleted()``), while undonated builds leave them live;
  - the compiled executable really aliases input->output buffers
    (``memory_analysis().alias_size_in_bytes > 0``) wherever shapes allow;
  - the Trainer threads ``donate``/``donate_eval`` through to the
    builders (previously the DP eval/serve paths silently dropped them).

CPU honours donation semantics (buffers are invalidated even when XLA:CPU
chooses not to reuse the allocation), so ``.is_deleted()`` is assertable
under JAX_PLATFORM_NAME=cpu.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.batching import BatchCapacities, batch_crystals
from repro.core.chgnet import CHGNetConfig, chgnet_apply, chgnet_init
from repro.core.neighbors import Crystal, build_graph
from repro.train import TrainConfig, Trainer
from repro.train.trainer import (
    make_chgnet_eval_serve_step,
    make_chgnet_step_fns,
    make_dp_eval_step,
    make_dp_serve_step,
)


def _batch(seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    cs = []
    for n in (4, 5):
        cs.append(Crystal(
            lattice=np.eye(3) * 3.6 + rng.normal(0, .05, (3, 3)),
            frac_coords=rng.random((n, 3)),
            atomic_numbers=rng.integers(1, 60, n),
            energy=float(rng.normal()),
            forces=rng.normal(0, .1, (n, 3)),
            stress=rng.normal(0, .1, (3, 3)),
            magmoms=np.abs(rng.normal(0, 1, n)),
        ))
    gs = [build_graph(c) for c in cs]
    caps = BatchCapacities(sum(c.num_atoms for c in cs) + 4,
                           sum(g.num_bonds for g in gs) + 8,
                           sum(g.num_angles for g in gs) + 8)
    return batch_crystals(cs, gs, caps, dtype=dtype)


@pytest.fixture(scope="module")
def cfg():
    return CHGNetConfig(dim=16, num_blocks=1, readout="direct")


@pytest.fixture(scope="module")
def tcfg():
    return TrainConfig(global_batch=2, total_steps=10)


def _first_float_leaf(tree):
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype,
                                                     jnp.floating):
            return leaf
    raise AssertionError("no float leaf")


def test_train_step_consumes_params_and_opt_state(cfg, tcfg):
    tr = Trainer(cfg, tcfg)
    train_step, _, _ = make_chgnet_step_fns(cfg, tcfg)
    params, opt_state = tr.params, tr.opt_state
    p_leaf = _first_float_leaf(params)
    o_leaf = _first_float_leaf(opt_state)
    new_params, new_opt, _ = train_step(params, opt_state, _batch(), 0)
    jax.block_until_ready(_first_float_leaf(new_params))
    assert p_leaf.is_deleted()
    assert o_leaf.is_deleted()
    # undonated build on the SAME config must not consume its inputs
    train_nd, _, _ = make_chgnet_step_fns(cfg, tcfg, donate=False)
    p2 = _first_float_leaf(new_params)
    out = train_nd(new_params, new_opt, _batch(), 1)
    jax.block_until_ready(_first_float_leaf(out[0]))
    assert not p2.is_deleted()


def test_serve_step_consumes_batch_and_aliases(cfg, tcfg):
    params = chgnet_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    _, _, serve = make_chgnet_step_fns(cfg, tcfg)
    batch = _batch()
    leaf = batch.frac_coords
    out = serve(params, batch)
    jax.block_until_ready(out["forces"])
    assert leaf.is_deleted()
    # the executable genuinely aliases donated input buffers into outputs
    jitted = jax.jit(lambda p, b: chgnet_apply(p, cfg, b),
                     donate_argnums=(1,))
    mem = jitted.lower(params, _batch()).compile().memory_analysis()
    assert mem.alias_size_in_bytes > 0


def test_dp_eval_step_donate_flag(cfg, tcfg):
    """DP eval: the donate flag must reach XLA.

    Eval outputs are scalar metrics, so no donated batch buffer is ever
    shape-compatible with an output — donation can only release buffers
    early, never alias them, and XLA:CPU leaves such "unusable" donated
    buffers live.  The observable that donation was REQUESTED is jax's
    donation warning: the donated build must raise it on first trace, the
    default build must not."""
    import warnings

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    params = chgnet_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)

    def dev_batch():
        return jax.tree.map(lambda x: jnp.asarray(np.asarray(x)[None]),
                            _batch())

    eval_nd = make_dp_eval_step(cfg, tcfg, mesh)
    b = dev_batch()
    leaf = b.frac_coords
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "error", message=".*donated buffers were not usable.*")
        jax.block_until_ready(eval_nd(params, b)["loss"])
    assert not leaf.is_deleted()

    eval_d = make_dp_eval_step(cfg, tcfg, mesh, donate=True)
    with pytest.warns(UserWarning,
                      match="donated buffers were not usable"):
        jax.block_until_ready(eval_d(params, dev_batch())["loss"])


def test_dp_serve_step_donates_batch(cfg, tcfg):
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    params = chgnet_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    serve = make_dp_serve_step(cfg, mesh)
    b = jax.tree.map(lambda x: jnp.asarray(np.asarray(x)[None]), _batch())
    leaf = b.frac_coords
    jax.block_until_ready(serve(params, b)["forces"])
    assert leaf.is_deleted()


def test_eval_serve_step_consumes_batch_and_aliases(cfg, tcfg):
    """The combined eval+serve step: ONE forward yields (metrics, outputs),
    the donated batch is consumed, and the lowering carries the
    input->output aliasing annotation (``tf.aliasing_output``) — the
    contract that lets the batch buffers back the serve outputs."""
    params = chgnet_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    step = make_chgnet_eval_serve_step(cfg, TrainConfig(global_batch=2,
                                                        total_steps=10))
    batch = _batch()
    leaf = batch.frac_coords
    metrics, out = step(params, batch)
    jax.block_until_ready(out["forces"])
    assert leaf.is_deleted()
    assert np.isfinite(float(metrics["loss"]))
    for k in ("energy", "forces", "magmom"):
        assert np.all(np.isfinite(np.asarray(out[k]))), k
    assert "tf.aliasing_output" in step.lower(params, _batch()).as_text()
    # and the executable genuinely aliases bytes, not just annotates
    mem = step.lower(params, _batch()).compile().memory_analysis()
    assert mem.alias_size_in_bytes > 0
    # undonated build: batch left live, no aliasing annotation
    step_nd = make_chgnet_eval_serve_step(
        cfg, TrainConfig(global_batch=2, total_steps=10), donate=False)
    b2 = _batch()
    leaf2 = b2.frac_coords
    m2, o2 = step_nd(params, b2)
    jax.block_until_ready(o2["forces"])
    assert not leaf2.is_deleted()
    assert "tf.aliasing_output" not in \
        step_nd.lower(params, _batch()).as_text()


def test_eval_serve_step_on_symmetric_trunk(tcfg):
    """The fused step composes with the §10 symmetric trunk tier."""
    sym_cfg = CHGNetConfig(dim=16, num_blocks=1, readout="direct",
                           bond_store="undirected",
                           bond_features="undirected")
    params = chgnet_init(jax.random.PRNGKey(0), sym_cfg,
                         dtype=jnp.float32)
    step = make_chgnet_eval_serve_step(sym_cfg, tcfg)
    metrics, out = step(params, _batch())
    jax.block_until_ready(out["forces"])
    assert np.isfinite(float(metrics["loss"]))
    assert np.all(np.isfinite(np.asarray(out["forces"])))


def test_trainer_threads_donation_flags(cfg, tcfg):
    """Trainer(donate=False) must leave params live after a step; the
    default consumes them.  Exercises _build_steps' threading, which is
    what the compile-cache ``donate`` keys exist for."""
    tr = Trainer(cfg, tcfg, donate=False)
    leaf = _first_float_leaf(tr.params)
    out = tr._train_step(tr.params, tr.opt_state, _batch(), 0)
    jax.block_until_ready(_first_float_leaf(out[0]))
    assert not leaf.is_deleted()

    tr2 = Trainer(cfg, tcfg)
    assert tr2.donate and not tr2.donate_eval
    leaf2 = _first_float_leaf(tr2.params)
    out2 = tr2._train_step(tr2.params, tr2.opt_state, _batch(), 0)
    jax.block_until_ready(_first_float_leaf(out2[0]))
    assert leaf2.is_deleted()
