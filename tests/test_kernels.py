"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("n", [1, 7, 512, 1333])
@pytest.mark.parametrize("k", [8, 31])
def test_fused_rbf_matches_oracle(n, k):
    d = jnp.asarray(RNG.uniform(0.2, 6.0, (n,)), jnp.float32)
    freqs = jnp.arange(1, k + 1, dtype=jnp.float32) * jnp.pi
    out = ops.fused_rbf(d, freqs, 6.0, 8)
    want = ref.fused_rbf_ref(d, freqs, 6.0, 8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("n", [3, 600, 1024])
@pytest.mark.parametrize("k", [9, 31])
def test_fused_fourier_matches_oracle(n, k):
    th = jnp.asarray(RNG.uniform(0, np.pi, (n,)), jnp.float32)
    out = ops.fused_fourier(th, k)
    want = ref.fused_fourier_ref(th, k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("m,d_in,d_out", [(64, 192, 64), (300, 256, 64),
                                          (17, 128, 32)])
def test_fused_gated_mlp_matches_oracle(m, d_in, d_out):
    x = jnp.asarray(RNG.normal(0, 1, (m, d_in)), jnp.float32)
    wc = jnp.asarray(RNG.normal(0, .1, (d_in, d_out)), jnp.float32)
    wg = jnp.asarray(RNG.normal(0, .1, (d_in, d_out)), jnp.float32)
    bc = jnp.asarray(RNG.normal(0, .1, (d_out,)), jnp.float32)
    bg = jnp.asarray(RNG.normal(0, .1, (d_out,)), jnp.float32)
    sc = jnp.asarray(RNG.uniform(.5, 1.5, (d_out,)), jnp.float32)
    sg = jnp.asarray(RNG.uniform(.5, 1.5, (d_out,)), jnp.float32)
    oc = jnp.asarray(RNG.normal(0, .1, (d_out,)), jnp.float32)
    og = jnp.asarray(RNG.normal(0, .1, (d_out,)), jnp.float32)
    out = ops.fused_gated_mlp(x, wc, bc, wg, bg, sc, oc, sg, og)
    want = ref.fused_gated_mlp_ref(x, wc, bc, wg, bg, sc, oc, sg, og)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("e,s,d,n_real", [
    (256, 8, 64, 256),    # aligned, no padding
    (1000, 37, 64, 850),  # unaligned everything + padded tail
    (64, 5, 16, 0),       # all edges padded
    (7, 3, 200, 5),       # tiny E, wide D
])
def test_fused_segment_sum_matches_oracle(e, s, d, n_real):
    ids = np.sort(RNG.integers(0, s, n_real)).astype(np.int32)
    seg = np.zeros(e, np.int32)
    seg[:n_real] = ids
    offs = np.searchsorted(ids, np.arange(s + 1)).astype(np.int32)
    v = RNG.normal(0, 1, (e, d)).astype(np.float32)
    v[n_real:] = 0.0  # padded payloads are zeroed by convention
    out = ops.fused_segment_sum(jnp.asarray(v), jnp.asarray(seg),
                                jnp.asarray(offs), s)
    want = ref.sorted_segment_sum_ref(jnp.asarray(v), jnp.asarray(seg),
                                      jnp.asarray(offs), s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("act", ["silu", "gelu"])
@pytest.mark.parametrize("m,d,f", [(128, 128, 512), (256, 64, 256)])
def test_fused_swiglu_matches_oracle(act, m, d, f):
    x = jnp.asarray(RNG.normal(0, 1, (m, d)), jnp.float32)
    w1 = jnp.asarray(RNG.normal(0, .05, (d, f)), jnp.float32)
    w2 = jnp.asarray(RNG.normal(0, .05, (d, f)), jnp.float32)
    w3 = jnp.asarray(RNG.normal(0, .05, (f, d)), jnp.float32)
    out = ops.fused_swiglu(x, w1, w2, w3, activation=act)
    if act == "silu":
        want = ref.fused_swiglu_ref(x, w1, w2, w3)
    else:
        want = (jax.nn.gelu(x @ w1, approximate=True) * (x @ w2)) @ w3
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("b,h,s,d", [(1, 2, 256, 64), (2, 4, 128, 128)])
def test_flash_attention_matches_oracle(causal, b, h, s, d):
    q = jnp.asarray(RNG.normal(0, 1, (b, h, s, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, h, s, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, h, s, d)), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.normal(0, 1, (1, 2, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(0, 1, (1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(0, 1, (1, 2, 128, 64)), jnp.bfloat16)
    out = ops.flash_attention(q, k, v, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_kernels_are_jittable():
    d = jnp.asarray(RNG.uniform(0.2, 6.0, (128,)), jnp.float32)
    freqs = jnp.arange(1, 32, dtype=jnp.float32) * jnp.pi
    out = jax.jit(lambda dd: ops.fused_rbf(dd, freqs, 6.0, 8))(d)
    assert out.shape == (128, 31)
