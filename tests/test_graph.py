"""Molecular graph extraction: PBC neighbor lists, bond graph, batching."""
import numpy as np
import pytest

from repro.core import BatchCapacities, Crystal, batch_crystals, build_graph


def brute_force_neighbors(c: Crystal, r_cut: float):
    """O(N^2 * images) reference neighbor count."""
    cart = c.cart_coords()
    n = c.num_atoms
    count = 0
    rng = range(-3, 4)
    for i in range(n):
        for j in range(n):
            for a in rng:
                for b in rng:
                    for cc in rng:
                        off = np.array([a, b, cc]) @ c.lattice
                        d = np.linalg.norm(cart[j] + off - cart[i])
                        if 1e-8 < d <= r_cut:
                            count += 1
    return count


def test_neighbor_list_matches_brute_force():
    rng = np.random.default_rng(0)
    c = Crystal(lattice=np.eye(3) * 5.0 + rng.normal(0, 0.1, (3, 3)),
                frac_coords=rng.random((5, 3)),
                atomic_numbers=rng.integers(1, 10, 5))
    g = build_graph(c, r_cut_atom=6.0)
    assert g.num_bonds == brute_force_neighbors(c, 6.0)


def test_bonds_are_directed_pairs():
    """Every (i, j, image) edge has its (j, i, -image) mirror."""
    rng = np.random.default_rng(1)
    c = Crystal(lattice=np.eye(3) * 4.0, frac_coords=rng.random((6, 3)),
                atomic_numbers=rng.integers(1, 10, 6))
    g = build_graph(c)
    edges = set(zip(g.bond_center.tolist(), g.bond_nbr.tolist(),
                    map(tuple, g.bond_image.tolist())))
    for (i, j, im) in edges:
        assert (j, i, tuple(-np.asarray(im))) in edges


def test_angles_share_center_and_short_cutoff():
    rng = np.random.default_rng(2)
    c = Crystal(lattice=np.eye(3) * 4.0, frac_coords=rng.random((8, 3)),
                atomic_numbers=rng.integers(1, 10, 8))
    g = build_graph(c, r_cut_atom=6.0, r_cut_bond=3.0)
    cart = c.cart_coords()
    vec = cart[g.bond_nbr] + g.bond_image @ c.lattice - cart[g.bond_center]
    dist = np.linalg.norm(vec, axis=-1)
    assert g.num_angles > 0
    # both bonds of every angle share the center atom and are <= 3 A
    assert (g.bond_center[g.angle_ij] == g.bond_center[g.angle_ik]).all()
    assert (dist[g.angle_ij] <= 3.0 + 1e-9).all()
    assert (dist[g.angle_ik] <= 3.0 + 1e-9).all()
    assert (g.angle_ij != g.angle_ik).all()


def test_translation_invariance_of_graph():
    """Shifting all frac coords (mod 1) preserves the distance multiset."""
    rng = np.random.default_rng(3)
    c1 = Crystal(lattice=np.eye(3) * 4.2, frac_coords=rng.random((6, 3)),
                 atomic_numbers=np.arange(1, 7))
    shift = rng.random(3)
    c2 = Crystal(lattice=c1.lattice,
                 frac_coords=(c1.frac_coords + shift) % 1.0,
                 atomic_numbers=c1.atomic_numbers)
    g1, g2 = build_graph(c1), build_graph(c2)
    assert g1.num_bonds == g2.num_bonds

    def dists(c, g):
        cart = c.cart_coords()
        v = cart[g.bond_nbr] + g.bond_image @ c.lattice - cart[g.bond_center]
        return np.sort(np.linalg.norm(v, axis=-1))

    np.testing.assert_allclose(dists(c1, g1), dists(c2, g2), rtol=1e-6)


def test_batching_masks_and_offsets():
    rng = np.random.default_rng(4)
    cs = [Crystal(lattice=np.eye(3) * 4.0, frac_coords=rng.random((n, 3)),
                  atomic_numbers=rng.integers(1, 10, n)) for n in (3, 5)]
    gs = [build_graph(c) for c in cs]
    caps = BatchCapacities(atoms=16,
                           bonds=sum(g.num_bonds for g in gs) + 7,
                           angles=sum(g.num_angles for g in gs) + 5)
    b = batch_crystals(cs, gs, caps)
    assert float(b.atom_mask.sum()) == 8
    assert float(b.bond_mask.sum()) == sum(g.num_bonds for g in gs)
    assert float(b.angle_mask.sum()) == sum(g.num_angles for g in gs)
    # second crystal's bonds index into its own atom range
    nb0 = gs[0].num_bonds
    assert int(b.bond_center[nb0]) >= 3
    # capacity overflow raises
    with pytest.raises(ValueError):
        batch_crystals(cs, gs, BatchCapacities(4, 8, 8))
